"""Durable streaming sessions (PR 9): WAL framing, snapshot+replay
recovery, the seeded crash-point matrix, and the memory-pressure ladder.

The headline pin (ISSUE 9 acceptance): **for every crash point in the
seeded chaos matrix, the recovered session is bit-identical — thresholds,
retained buffer, PRNG key state, element counter, summary — to one that
never crashed**, on both backends, with zero lost sessions.  Supporting
invariants:

- WAL reads fail loudly: a checksum/framing violation raises
  :class:`WALCorrupt` and never silently drops acknowledged suffix
  records; only the never-acknowledged torn tail is skippable, by explicit
  opt-in.
- Batched waves are invisible: a multi-session engine computes per-session
  states bit-identical to per-session B=1 engines (the property recovery
  replay leans on).
- The eviction ladder (evict → snapshot+release → lazy rehydrate) changes
  *where* state lives, never *what* it is, and every rung leaves an
  auditable event.
"""

import dataclasses
import os

import numpy as np
import pytest
import jax.tree_util as jtu

import repro.api as api
from repro.serve import wal
from repro.serve.sessions import SessionConfig, SessionEngine
from repro.serve.faults import Fault, FaultInjected, FaultPlan
from repro.serve.summarize_service import ServiceRestarted

BACKENDS = ["oracle", "pallas"]


def cfg_small(**kw):
    base = dict(
        k=3, eps=0.5, n_features=12, buffer_cap=12, resparsify_every=5,
        ss_r=2, ss_c=6.0, max_batch=4, snapshot_every=12,
    )
    base.update(kw)
    return SessionConfig(**base)


def rows_for(seed, n=36, F=12, drift=6.0):
    """A drifting stream: magnitudes grow so the sieve window keeps
    sliding, elements keep being accepted, and SS compaction fires."""
    r = np.random.default_rng(seed)
    scale = 1.0 + drift * np.arange(n, dtype=np.float32) / n
    return r.random((n, F)).astype(np.float32) * scale[:, None]


def assert_states_equal(a, b, what=""):
    la = jtu.tree_leaves_with_path(a)
    lb = jtu.tree_leaves_with_path(b)
    assert len(la) == len(lb)
    for (pa, x), (_, y) in zip(la, lb):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"{what} state leaf {jtu.keystr(pa)} differs",
        )


def assert_summaries_equal(a, b):
    np.testing.assert_array_equal(a.selected, b.selected)
    np.testing.assert_array_equal(a.gains, b.gains)
    assert a.value == b.value and a.sieve_value == b.sieve_value
    assert (a.retained, a.seen, a.drops, a.resparsifies) == (
        b.retained, b.seen, b.drops, b.resparsifies)


def run_reference(cfg, root, streams):
    """The uninterrupted run: every stream fully ingested and flushed."""
    eng = SessionEngine(cfg, root)
    for sid in streams:
        eng.open_session(sid=sid, key=int(sid[1:]))
    n = max(len(v) for v in streams.values())
    for t in range(n):
        for sid, R in streams.items():
            if t < len(R):
                eng.append(sid, R[t])
    eng.flush()
    return eng


# ------------------------------------------------------------- WAL layer ----

def test_wal_roundtrip(tmp_path):
    p = str(tmp_path / "wal.log")
    w = wal.WalWriter(p)
    payloads = [b"open-meta", b"row-one", b"", b"x" * 1000]
    for i, pl in enumerate(payloads):
        w.append(wal.OPEN if i == 0 else wal.APPEND, i, pl)
    size = w.tell()
    w.close()
    assert os.path.getsize(p) == size
    scan = wal.scan_wal(p)
    recs = scan.records
    assert scan.valid_end == size and scan.torn_bytes == 0
    assert [r.seq for r in recs] == [0, 1, 2, 3]
    assert [r.payload for r in recs] == payloads
    assert recs[0].rtype == wal.OPEN
    assert all(r.rtype == wal.APPEND for r in recs[1:])


def test_wal_checksum_corruption_fails_loudly(tmp_path):
    """A flipped bit mid-log raises WALCorrupt — the suffix records after
    it are acknowledged data and must never be silently dropped."""
    p = str(tmp_path / "wal.log")
    w = wal.WalWriter(p)
    for i in range(5):
        w.append(wal.APPEND, i, bytes([i]) * 32)
    w.close()
    data = bytearray(open(p, "rb").read())
    # flip a payload byte of the middle record (records end with payload,
    # so 10 bytes before a record boundary is always inside a payload)
    rec_size = len(data) // 5
    data[3 * rec_size - 10] ^= 0xFF
    open(p, "wb").write(bytes(data))
    with pytest.raises(wal.WALCorrupt):
        wal.scan_wal(p)
    # even opting into torn-tail tolerance must not skip mid-file damage
    with pytest.raises(wal.WALCorrupt) as ei:
        wal.scan_wal(p, tolerate_torn_tail=True)
    assert not isinstance(ei.value, wal.WALTruncated)


def test_wal_torn_tail(tmp_path):
    """EOF mid-final-record is the crash-mid-write signature: raises
    WALTruncated by default; tolerate_torn_tail returns the complete
    prefix (the partial record was never acknowledged)."""
    p = str(tmp_path / "wal.log")
    w = wal.WalWriter(p)
    for i in range(4):
        w.append(wal.APPEND, i, bytes(64))
    w.close()
    full = open(p, "rb").read()
    rec_size = len(full) // 4
    for cut in (70, 30):  # mid-header and mid-payload of the last record
        open(p, "wb").write(full[: len(full) - cut])
        with pytest.raises(wal.WALTruncated):
            wal.scan_wal(p)
        scan = wal.scan_wal(p, tolerate_torn_tail=True)
        assert [r.seq for r in scan.records] == [0, 1, 2]
        # valid_end frames the complete prefix; torn_bytes the partial rest
        assert scan.valid_end == 3 * rec_size
        assert scan.torn_bytes == rec_size - cut


# ------------------------------------------------------------- engine -------

def test_volatile_round_trip_and_validation():
    eng = SessionEngine(cfg_small())
    sid = eng.open_session(key=1)
    R = rows_for(1)
    for t in range(len(R)):
        eng.append(sid, R[t])
    s = eng.summary(sid)
    assert s.seen == len(R)
    assert 0 < s.retained <= eng.config.buffer_cap
    assert s.value > 0 and s.sieve_value > 0
    assert len(s.selected) <= eng.config.k
    assert (s.selected >= 0).all() and (s.selected < s.seen).all()
    assert s.resparsifies > 0        # the SS tier actually engaged
    with pytest.raises(KeyError):
        eng.append("nope", R[0])
    with pytest.raises(ValueError, match="shape"):
        eng.append(sid, np.zeros(5, np.float32))
    with pytest.raises(ValueError, match="finite"):
        eng.append(sid, np.full(12, np.nan, np.float32))
    with pytest.raises(ValueError, match="already exists"):
        eng.open_session(sid=sid)
    with pytest.raises(ValueError, match="session id"):
        eng.open_session(sid="../escape")
    with pytest.raises(ValueError, match="root"):
        SessionEngine(cfg_small(max_live_sessions=1))


def test_batched_waves_match_single_session_engines():
    """A 3-session engine (waves pad/stack sessions) must produce states
    bit-identical to three isolated B=1 engines — the vmap-row-identity
    contract that also underwrites B=1 recovery replay."""
    cfg = cfg_small()
    multi = SessionEngine(cfg)
    sids = [multi.open_session(sid=f"s{i}", key=i) for i in range(3)]
    streams = {s: rows_for(i, n=30 + 2 * i) for i, s in enumerate(sids)}
    for t in range(34):
        for s in sids:
            if t < len(streams[s]):
                multi.append(s, streams[s][t])
    for i, s in enumerate(sids):
        solo = SessionEngine(cfg)
        alone = solo.open_session(sid=s, key=i)
        for t in range(len(streams[s])):
            solo.append(alone, streams[s][t])
        assert_states_equal(
            multi.state(s), solo.state(alone), f"session {s}"
        )
        assert_summaries_equal(multi.summary(s), solo.summary(alone))


@pytest.mark.parametrize("snapshot_every", [6, None])
def test_durable_recovery_bit_identical(tmp_path, snapshot_every):
    """Reopening a root recovers every session bit-identically — via
    snapshot + WAL tail, or (snapshot_every=None) by full WAL replay."""
    cfg = cfg_small(snapshot_every=snapshot_every)
    root = str(tmp_path / "eng")
    streams = {f"u{i}": rows_for(i) for i in range(2)}
    ref = run_reference(cfg, root, streams)
    states = {s: ref.state(s) for s in streams}
    summaries = {s: ref.summary(s) for s in streams}

    rec = SessionEngine(cfg, root)
    assert rec.sessions() == sorted(streams)       # zero lost sessions
    for s in streams:
        assert_states_equal(states[s], rec.state(s), f"recovered {s}")
        assert_summaries_equal(summaries[s], rec.summary(s))
    ev = [e for e in rec.events if e["step"] == "rehydrate"]
    assert len(ev) == len(streams)
    if snapshot_every is None:
        assert all(e["replayed"] == len(rows_for(0)) for e in ev)


def test_recovery_can_continue_ingesting(tmp_path):
    """A recovered session is not read-only: appends continue with the
    same sequence numbering and reach the same state as a process that
    never died."""
    cfg = cfg_small()
    root = str(tmp_path / "eng")
    R = rows_for(4, n=40)
    ref = run_reference(cfg, str(tmp_path / "ref"), {"u4": R})
    half = SessionEngine(cfg, root)
    half.open_session(sid="u4", key=4)
    for t in range(20):
        half.append("u4", R[t])
    half.flush()
    del half
    rec = SessionEngine(cfg, root)
    for t in range(20, 40):
        rec.append("u4", R[t])
    assert_states_equal(ref.state("u4"), rec.state("u4"), "continued")
    assert_summaries_equal(ref.summary("u4"), rec.summary("u4"))


def test_snapshot_fallback_on_corrupt_latest(tmp_path):
    """A corrupt newest snapshot falls back to its predecessor (longer WAL
    replay, same bits) and leaves an auditable snapshot_fallback event."""
    cfg = cfg_small(snapshot_every=6)
    root = str(tmp_path / "eng")
    ref = run_reference(cfg, root, {"u0": rows_for(0)})
    want_state, want_sum = ref.state("u0"), ref.summary("u0")
    sdir = os.path.join(root, "u0")
    snaps = sorted(n for n in os.listdir(sdir) if n.startswith("snap-"))
    assert len(snaps) == 2                      # engine keeps the newest two
    with open(os.path.join(sdir, snaps[-1]), "r+b") as f:
        f.seek(100)
        f.write(b"\xff" * 50)
    rec = SessionEngine(cfg, root)
    assert_states_equal(want_state, rec.state("u0"), "fallback")
    assert_summaries_equal(want_sum, rec.summary("u0"))
    assert rec.stats()["snapshot_fallbacks"] == 1
    (ev,) = [e for e in rec.events if e["step"] == "snapshot_fallback"]
    assert ev["snapshot"] == snaps[-1]


def test_corrupt_wal_tail_handling(tmp_path):
    """Recovery surfaces WAL damage instead of replaying an edited
    history: mid-file corruption always raises; a torn tail raises unless
    the config explicitly tolerates losing the unacknowledged record."""
    cfg = cfg_small(snapshot_every=None)
    root = str(tmp_path / "eng")
    ref = run_reference(cfg, root, {"u0": rows_for(0, n=20)})
    del ref
    p = os.path.join(root, "u0", "wal.log")
    full = open(p, "rb").read()
    # torn tail: drop the last 7 bytes of the final record
    open(p, "wb").write(full[:-7])
    with pytest.raises(wal.WALTruncated):
        SessionEngine(cfg, root).state("u0")
    tol = SessionEngine(
        dataclasses.replace(cfg, tolerate_torn_tail=True), root
    )
    st = tol.state("u0")
    assert int(st.sieve.t) == 19               # only the torn record lost
    # mid-file corruption: never skippable, tolerant or not.  The last
    # APPEND record occupies the final 69 bytes (21 header + 48 payload);
    # 10 bytes before its start is a payload byte of the record before it.
    data = bytearray(full)
    data[len(data) - 69 - 10] ^= 0xFF
    open(p, "wb").write(bytes(data))
    for cfg_try in (cfg, dataclasses.replace(cfg, tolerate_torn_tail=True)):
        with pytest.raises(wal.WALCorrupt):
            SessionEngine(cfg_try, root).state("u0")


def test_torn_tail_recovery_truncates_wal_and_appends_survive(tmp_path):
    """Tolerated torn tails must be physically truncated at recovery: the
    WAL writer appends, so a record written after leftover partial bytes
    would misframe every later scan at the torn offset — acknowledged
    post-recovery appends would be permanently unrecoverable."""
    cfg = cfg_small(snapshot_every=None, tolerate_torn_tail=True)
    root = str(tmp_path / "eng")
    R = rows_for(7, n=30)
    ref = run_reference(cfg, str(tmp_path / "ref"), {"u7": R})
    half = SessionEngine(cfg, root)
    half.open_session(sid="u7", key=7)
    for t in range(20):
        half.append("u7", R[t])
    half.flush()
    del half
    p = os.path.join(root, "u7", "wal.log")
    os.truncate(p, os.path.getsize(p) - 7)      # crash mid-write of seq 20
    rec = SessionEngine(cfg, root)
    assert int(rec.state("u7").sieve.t) == 19   # only the torn record lost
    assert rec.stats()["wal_truncations"] == 1
    (ev,) = [e for e in rec.events if e["step"] == "wal_truncate"]
    assert ev["sid"] == "u7" and ev["dropped_bytes"] == 69 - 7
    assert os.path.getsize(p) == ev["valid_end"]    # partial bytes are gone
    # acknowledged appends made AFTER the recovery must survive the next
    # one: re-ingest the lost element and finish the stream, then reopen
    # with a STRICT config — pre-fix, the new records sat after the torn
    # garbage and this scan raised WALCorrupt, losing all of them.
    for t in range(19, 30):
        rec.append("u7", R[t])
    rec.flush()
    del rec
    strict = SessionEngine(
        dataclasses.replace(cfg, tolerate_torn_tail=False), root
    )
    assert_states_equal(ref.state("u7"), strict.state("u7"), "post-torn")
    assert_summaries_equal(ref.summary("u7"), strict.summary("u7"))


def test_volatile_engine_rejects_crash_restart_faults():
    """crash/restart faults presume durable storage to recover from; on a
    volatile engine acknowledged appends would be silently lost, so the
    plan is rejected at construction (kinds that lose nothing stay fine)."""
    for kind in ("crash", "restart"):
        with pytest.raises(ValueError, match="volatile"):
            SessionEngine(cfg_small(), faults=FaultPlan({0: Fault(kind)}))
    SessionEngine(cfg_small(), faults=FaultPlan({0: Fault("exec_error")}))
    SessionEngine(
        cfg_small(), None, faults=FaultPlan({1: Fault("latency")})
    )


def test_config_signature_mismatch_refuses_replay(tmp_path):
    """Replaying a WAL under a different trajectory config would silently
    fabricate a different state — recovery must refuse instead."""
    cfg = cfg_small()
    root = str(tmp_path / "eng")
    run_reference(cfg, root, {"u0": rows_for(0, n=10)})
    other = SessionEngine(dataclasses.replace(cfg, k=4), root)
    with pytest.raises(ValueError, match="different"):
        other.state("u0")


# ------------------------------------------------- crash-point chaos matrix -

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("seed", [0, 1])
def test_crash_point_matrix_replay_exactness(tmp_path, backend, seed):
    """THE acceptance pin: crash the engine at every fault-attempt index
    that fires mid-stream; after recovery + finishing the stream, state
    and summary are bit-identical to the uninterrupted run, with zero
    lost sessions — on both backends."""
    cfg = cfg_small(backend=backend, snapshot_every=8)
    streams = {f"u{i}": rows_for(100 * seed + i, n=28) for i in range(2)}
    ref = run_reference(cfg, str(tmp_path / f"ref{seed}"), streams)
    want = {s: ref.state(s) for s in streams}
    want_sum = {s: ref.summary(s) for s in streams}

    crash_points = (0, 2, 5, 9)
    for cp in crash_points:
        root = str(tmp_path / f"c{seed}-{cp}")
        eng = SessionEngine(cfg, root, faults=FaultPlan({cp: Fault("crash")}))
        crashed = False
        try:
            for s in streams:
                eng.open_session(sid=s, key=int(s[1:]))
            for t in range(28):
                for s in streams:
                    eng.append(s, streams[s][t])
            eng.flush()
        except ServiceRestarted:
            crashed = True
        assert crashed, f"crash point {cp} was never reached"
        # recovery: a fresh engine on the same root.  Everything acked —
        # including the append whose auto-flush crashed — is in the WAL;
        # the durable element count is the replayed sieve counter.
        rec = SessionEngine(cfg, root)
        assert rec.sessions() == sorted(streams)   # zero lost sessions
        for s in streams:
            done = int(rec.state(s).sieve.t)
            for t in range(done, 28):
                rec.append(s, streams[s][t])
        rec.flush()
        for s in streams:
            assert_states_equal(want[s], rec.state(s),
                                f"crash@{cp} session {s}")
            assert_summaries_equal(want_sum[s], rec.summary(s))


def test_restart_fault_is_transparent(tmp_path):
    """A restart fault (kill + in-place reopen) mid-stream: acknowledged
    elements replay from disk on next touch, and the final state matches
    the fault-free run exactly."""
    cfg = cfg_small(snapshot_every=8)
    R = rows_for(9, n=32)
    ref = run_reference(cfg, str(tmp_path / "ref"), {"u9": R})
    plan = FaultPlan({2: Fault("restart"), 6: Fault("restart")})
    eng = SessionEngine(cfg, str(tmp_path / "eng"), faults=plan)
    eng.open_session(sid="u9", key=9)
    for t in range(32):
        eng.append("u9", R[t])
    eng.flush()
    assert eng.stats()["restarts"] == 2
    assert [e["step"] for e in eng.events].count("restart") == 2
    assert_states_equal(ref.state("u9"), eng.state("u9"), "restart")
    assert_summaries_equal(ref.summary("u9"), eng.summary("u9"))


def test_exec_error_wave_loses_nothing(tmp_path):
    """An injected wave execution error aborts the flush with pending
    elements intact; the retried flush lands the identical state."""
    cfg = cfg_small()
    R = rows_for(3, n=10)
    ref = run_reference(cfg, str(tmp_path / "ref"), {"u3": R})
    eng = SessionEngine(
        cfg, str(tmp_path / "eng"),
        faults=FaultPlan({0: Fault("exec_error")}),
    )
    eng.open_session(sid="u3", key=3)
    with pytest.raises(FaultInjected):
        for t in range(10):
            eng.append("u3", R[t])
    done = int(eng.state("u3").sieve.t)
    for t in range(done, 10):      # state() flushed the survivors already
        eng.append("u3", R[t])
    assert_states_equal(ref.state("u3"), eng.state("u3"), "exec_error")


# ------------------------------------------------------- memory ladder ------

def test_eviction_ladder_preserves_state(tmp_path):
    """With max_live_sessions=2 and 4 active streams the engine must evict
    (snapshot+release) and rehydrate constantly — and still finish with
    states bit-identical to an unconstrained engine."""
    cfg = cfg_small(max_live_sessions=2, snapshot_every=8)
    free = dataclasses.replace(cfg, max_live_sessions=None)
    streams = {f"e{i}": rows_for(i, n=20) for i in range(4)}
    ref = run_reference(free, str(tmp_path / "ref"), streams)
    eng = run_reference(cfg, str(tmp_path / "eng"), streams)
    st = eng.stats()
    assert st["live_sessions"] <= 2
    assert st["evictions"] > 0 and st["rehydrations"] > 0
    steps = [e["step"] for e in eng.events]
    assert "evict" in steps and "rehydrate" in steps
    ev = next(e for e in eng.events if e["step"] == "evict")
    assert ev["reason"] == "pressure" and "sid" in ev and "live" in ev
    for s in streams:
        assert_states_equal(ref.state(s), eng.state(s), f"ladder {s}")


def test_read_path_enforces_memory_cap(tmp_path):
    """summary()/state() hydrate sessions too — a read-heavy sweep over
    many sessions must hold max_live_sessions between flushes, not just
    on the write path."""
    cfg = cfg_small(max_live_sessions=2, snapshot_every=8)
    streams = {f"r{i}": rows_for(i, n=12) for i in range(5)}
    eng = run_reference(cfg, str(tmp_path / "eng"), streams)
    assert eng.stats()["live_sessions"] <= 2
    for s in streams:           # hydrate every session through reads only
        eng.summary(s)
        assert eng.stats()["live_sessions"] <= 2
    for s in streams:
        eng.state(s)
        assert eng.stats()["live_sessions"] <= 2


def test_close_snapshots_for_fast_reopen(tmp_path):
    cfg = cfg_small(snapshot_every=1000)   # interval policy never fires
    root = str(tmp_path / "eng")
    with SessionEngine(cfg, root) as eng:
        eng.open_session(sid="u0", key=0)
        for r in rows_for(0, n=9):
            eng.append("u0", r)
        want = eng.state("u0")
    with pytest.raises(RuntimeError, match="closed"):
        eng.summary("u0")
    rec = SessionEngine(cfg, root)
    assert_states_equal(want, rec.state("u0"), "reopen")
    (ev,) = [e for e in rec.events if e["step"] == "rehydrate"]
    assert ev["replayed"] == 0             # close() snapshotted everything


# ------------------------------------------------------------- api facade ---

def test_api_sessions_facade(tmp_path):
    root = str(tmp_path / "api")
    eng = api.sessions(SessionConfig(k=3, eps=0.5, n_features=12,
                                     buffer_cap=12), root)
    sid = api.open_session(key=1, engine=eng)
    R = rows_for(1, n=15)
    seqs = [api.append(sid, R[t], engine=eng) for t in range(15)]
    assert seqs == list(range(1, 16))      # contiguous durable acks
    s = api.summary(sid, engine=eng)
    assert s.sid == sid and s.seen == 15 and s.value > 0
    # the recovered view through a fresh facade engine is identical
    eng2 = api.sessions(eng.config, root)
    assert_summaries_equal(s, api.summary(sid, engine=eng2))


def test_api_default_engine_rejects_mismatched_root(tmp_path):
    """default_engine() must not hand the live volatile engine to a caller
    who asked for a durable root — that caller would believe their acks
    survive a crash when they do not (and vice versa: a differently-rooted
    request never silently lands on the wrong store)."""
    saved = api._default_engine
    api._default_engine = None
    try:
        eng = api.default_engine()              # volatile first use
        assert api.default_engine() is eng      # no root asked: fine
        with pytest.raises(ValueError, match="rooted"):
            api.default_engine(root=str(tmp_path / "durable"))
        with pytest.raises(ValueError, match="configured"):
            api.default_engine(SessionConfig(k=5))
        # a durable default likewise refuses a *different* root
        api._default_engine = None
        rooted = api.default_engine(root=str(tmp_path / "a"))
        assert api.default_engine(root=str(tmp_path / "a")) is rooted
        with pytest.raises(ValueError, match="rooted"):
            api.default_engine(root=str(tmp_path / "b"))
    finally:
        api._default_engine = saved
