"""Micro-batched summarization service + the batched core entry points.

The contract under test (docs/serving.md): micro-batching is a pure
execution strategy.  Each query's results — SS ``vprime`` / ``eps_hat`` /
``rounds`` / ``alive_trace`` and greedy ``selected`` / ``gains`` / ``value``
— are *identical* to a sequential single-query ``ss_sparsify`` + ``greedy``
run under the same per-query key, regardless of batch composition (mixed n
and k in one flush), batch-bucket padding (non-bucket-multiple batch
sizes), or backend (oracle / pallas)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FacilityLocation,
    FeatureCoverage,
    PallasBackend,
    greedy,
    greedy_batched,
    ss_live_bound,
    ss_sparsify,
    ss_sparsify_batched,
)
from repro.data import news_day
from repro.serve import (
    RunConfig,
    SummarizeRequest,
    SummarizeService,
    batch_buckets,
    summarize_batch,
)

BACKENDS = {
    "oracle": lambda: "oracle",
    "pallas": lambda: PallasBackend(interpret=True),
}


def make_fc_batch(B=3, n=256, F=64, seed=0):
    Ws = jnp.stack([jnp.asarray(news_day(seed + i, n, F)) for i in range(B)])
    return FeatureCoverage(W=Ws, phi="sqrt"), [
        FeatureCoverage(W=Ws[i], phi="sqrt") for i in range(B)
    ]


def _assert_rows_equal_sequential(ssb, gb, fns, keys, k, be):
    for i, fn in enumerate(fns):
        ss = ss_sparsify(fn, keys[i], backend=be)
        res = greedy(fn, k, alive=ss.vprime, backend=be)
        assert (np.asarray(ssb.vprime[i]) == np.asarray(ss.vprime)).all(), i
        assert float(ssb.eps_hat[i]) == float(ss.eps_hat), i
        assert int(ssb.rounds[i]) == int(ss.rounds), i
        assert (
            np.asarray(ssb.alive_trace[i]) == np.asarray(ss.alive_trace)
        ).all(), i
        assert (
            np.asarray(gb.selected[i]) == np.asarray(res.selected)
        ).all(), i
        np.testing.assert_allclose(
            np.asarray(gb.gains[i]), np.asarray(res.gains),
            rtol=1e-5, atol=1e-6,
        )
        np.testing.assert_allclose(
            float(gb.value[i]), float(res.value), rtol=1e-5)


# ------------------------------------------------- batched core entry points --
@pytest.mark.parametrize("backend", sorted(BACKENDS))
def test_batched_ss_and_greedy_match_sequential(backend):
    """Acceptance: row b of the batched pipeline is identical to the
    sequential single-query pipeline under the same key, on every dense
    backend."""
    be = BACKENDS[backend]()
    fnb, fns = make_fc_batch(B=3, n=256, F=64)
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    ssb = ss_sparsify_batched(fnb, keys, backend=be)
    gb = greedy_batched(fnb, 8, alive=ssb.vprime, backend=be)
    _assert_rows_equal_sequential(ssb, gb, fns, keys, 8, be)


def test_batched_ss_facility_location():
    Xs = jnp.stack([
        jax.random.normal(jax.random.PRNGKey(10 + i), (200, 12))
        for i in range(3)
    ])
    sims = jax.vmap(
        lambda X: FacilityLocation.from_features(X, kernel="cosine").sim
    )(Xs)
    fnb = FacilityLocation(sim=sims)
    fns = [FacilityLocation(sim=sims[i]) for i in range(3)]
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    ssb = ss_sparsify_batched(fnb, keys)
    gb = greedy_batched(fnb, 6, alive=ssb.vprime)
    _assert_rows_equal_sequential(ssb, gb, fns, keys, 6, "oracle")


def test_batched_ss_rows_freeze_independently():
    """Rows with very different live counts finish at different rounds; the
    early-finishing row's result must not drift while the rest iterate."""
    fnb, fns = make_fc_batch(B=2, n=256, F=32, seed=7)
    keys = jax.random.split(jax.random.PRNGKey(3), 2)
    # Row 0 starts with a tiny alive set (finishes immediately); row 1 full.
    alive = jnp.stack([jnp.arange(256) < 20, jnp.ones((256,), bool)])
    ssb = ss_sparsify_batched(fnb, keys, alive=alive)
    for i in range(2):
        ss = ss_sparsify(fns[i], keys[i], alive=alive[i])
        assert (np.asarray(ssb.vprime[i]) == np.asarray(ss.vprime)).all(), i
        assert int(ssb.rounds[i]) == int(ss.rounds), i


def test_batched_ss_importance_and_state():
    fnb, fns = make_fc_batch(B=2, n=200, F=32, seed=11)
    keys = jax.random.split(jax.random.PRNGKey(5), 2)
    states = jnp.stack([
        fns[i].add_many(fns[i].empty_state(), jnp.arange(200) < 3)
        for i in range(2)
    ])
    ssb = ss_sparsify_batched(fnb, keys, state=states, importance=True)
    for i in range(2):
        ss = ss_sparsify(fns[i], keys[i], state=states[i], importance=True)
        assert (np.asarray(ssb.vprime[i]) == np.asarray(ss.vprime)).all(), i
        assert float(ssb.eps_hat[i]) == float(ss.eps_hat), i


def test_greedy_batched_edge_cases():
    """Exhausted rows (k > |alive|), conditional state, and the loud bound
    check all mirror the single-query engine."""
    fnb, fns = make_fc_batch(B=2, n=128, F=24, seed=21)
    # row 0 exhausts after 3 selections, row 1 has plenty
    alive = jnp.stack([jnp.arange(128) < 3, jnp.arange(128) < 60])
    gb = greedy_batched(fnb, 6, alive=alive)
    for i in range(2):
        ref = greedy(fns[i], 6, alive=alive[i])
        assert (np.asarray(gb.selected[i]) == np.asarray(ref.selected)).all()
        np.testing.assert_allclose(
            np.asarray(gb.gains[i]), np.asarray(ref.gains),
            rtol=1e-5, atol=1e-6)
    assert (np.asarray(gb.selected[0])[3:] == 0).all()
    assert np.allclose(np.asarray(gb.gains[0])[3:], 0.0)

    states = jnp.stack([
        fns[i].add_many(fns[i].empty_state(), jnp.arange(128) < 2)
        for i in range(2)
    ])
    gbs = greedy_batched(fnb, 4, alive=alive, state=states)
    for i in range(2):
        ref = greedy(fns[i], 4, alive=alive[i], state=states[i])
        assert (np.asarray(gbs.selected[i]) == np.asarray(ref.selected)).all()

    with pytest.raises(ValueError, match="live bound"):
        greedy_batched(fnb, 4, alive=alive, compact=10)
    with pytest.raises(ValueError, match="alive mask"):
        greedy_batched(fnb, 4, alive=alive[0])


def test_greedy_batched_full_width_and_bound():
    """alive=None runs full width; an int bound compacts under a tracer mask
    (the jit/vmap service case) with unchanged selections."""
    fnb, fns = make_fc_batch(B=2, n=128, F=24, seed=31)
    gb = greedy_batched(fnb, 5)
    for i in range(2):
        ref = greedy(fns[i], 5)
        assert (np.asarray(gb.selected[i]) == np.asarray(ref.selected)).all()

    alive = jnp.stack([jnp.arange(128) < 40, jnp.arange(128) < 25])
    bound = ss_live_bound(128)
    sel_auto = greedy_batched(fnb, 5, alive=alive).selected
    sel_jit = jax.jit(
        lambda a: greedy_batched(fnb, 5, alive=a, compact=bound).selected
    )(alive)
    np.testing.assert_array_equal(np.asarray(sel_auto), np.asarray(sel_jit))


# ---------------------------------------------------------------- service ----
def test_service_mixed_lanes_match_sequential():
    """Acceptance: one flush with mixed n and k (two lanes) and a
    non-bucket-multiple batch size — every response identical to the
    sequential public-API pipeline under its own key."""
    svc = SummarizeService(RunConfig(backend="oracle", max_batch=8))
    reqs = [
        SummarizeRequest(
            k=8, key=i, features=jnp.asarray(news_day(i, 256, 64)))
        for i in range(5)                       # 5 -> B-bucket 8 (3 padded)
    ] + [
        SummarizeRequest(
            k=5, key=100 + i, features=jnp.asarray(news_day(50 + i, 200, 48)))
        for i in range(3)                       # second lane: different n, k
    ]
    out = svc.run(reqs)
    for i, (req, resp) in enumerate(zip(reqs, out)):
        fn = FeatureCoverage(W=jnp.asarray(req.features), phi="sqrt")
        ss = ss_sparsify(fn, req.prng_key())
        ref = greedy(fn, req.k, alive=ss.vprime)
        assert (np.asarray(resp.selected) == np.asarray(ref.selected)).all(), i
        np.testing.assert_allclose(
            np.asarray(resp.gains), np.asarray(ref.gains),
            rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(resp.value, float(ref.value), rtol=1e-5)
        assert resp.vprime_size == int(jnp.sum(ss.vprime))
        assert resp.eps_hat == float(ss.eps_hat)
        assert resp.rounds == int(ss.rounds)
    st = svc.stats()
    assert st["queries"] == 8 and st["batches"] == 2
    assert st["compiled_signatures"] == 2
    # lane 1 pads 5 -> bucket 8, lane 2 pads 3 -> bucket 4: 4 of 12 slots
    assert st["padding_waste_frac"] == pytest.approx(4 / 12)
    assert st["queue_delay_s_max"] >= st["queue_delay_s_mean"] >= 0.0
    assert all(r.batch_bucket >= r.batch_size for r in out)


def test_service_pallas_matches_sequential_pallas():
    """Interpret-mode kernels match the batched jnp arithmetic bitwise at
    shipped feature widths, so the cross-strategy pin is exact here;
    compiled-kernel runs are only guaranteed fp-close (docs/serving.md)."""
    be = PallasBackend(interpret=True)
    svc = SummarizeService(RunConfig(backend=be, max_batch=4))
    reqs = [
        SummarizeRequest(
            k=6, key=i, features=jnp.asarray(news_day(i, 256, 128)))
        for i in range(3)
    ]
    out = svc.run(reqs)
    for req, resp in zip(reqs, out):
        fn = FeatureCoverage(W=jnp.asarray(req.features), phi="sqrt")
        ss = ss_sparsify(fn, req.prng_key(), backend=be)
        ref = greedy(fn, req.k, alive=ss.vprime, backend=be)
        assert (np.asarray(resp.selected) == np.asarray(ref.selected)).all()
        assert resp.vprime_size == int(jnp.sum(ss.vprime))


def test_service_fl_and_no_ss_lanes():
    svc = SummarizeService(RunConfig(backend="oracle"))
    X = jax.random.normal(jax.random.PRNGKey(3), (180, 16))
    out = svc.run([
        SummarizeRequest(k=5, key=7, features=X, objective="fl"),
        SummarizeRequest(k=5, key=8, features=jnp.abs(X), use_ss=False),
    ])
    fn1 = FacilityLocation.from_features(X, kernel="cosine")
    ss1 = ss_sparsify(fn1, jax.random.PRNGKey(7))
    ref1 = greedy(fn1, 5, alive=ss1.vprime)
    assert (np.asarray(out[0].selected) == np.asarray(ref1.selected)).all()
    ref2 = greedy(FeatureCoverage(W=jnp.abs(X), phi="sqrt"), 5)
    assert (np.asarray(out[1].selected) == np.asarray(ref2.selected)).all()
    assert out[1].vprime_size is None and out[1].eps_hat is None
    # precomputed-sim payload lane
    out2 = svc.run([SummarizeRequest(k=4, key=9, sim=fn1.sim,
                                     objective="fl")])
    ss2 = ss_sparsify(fn1, jax.random.PRNGKey(9))
    ref3 = greedy(fn1, 4, alive=ss2.vprime)
    assert (np.asarray(out2[0].selected) == np.asarray(ref3.selected)).all()


def test_service_fl_sim_and_feature_payloads_do_not_collide():
    """A precomputed (n, n) sim payload and an (n, n) *feature* payload hash
    to different lanes — stacking them together would crash (or silently
    treat features as similarities)."""
    svc = SummarizeService(RunConfig(backend="oracle"))
    X = jnp.abs(jax.random.normal(jax.random.PRNGKey(4), (48, 48)))
    fn = FacilityLocation.from_features(X, kernel="cosine")
    out = svc.run([
        SummarizeRequest(k=4, key=1, features=X, objective="fl"),
        SummarizeRequest(k=4, key=2, sim=fn.sim, objective="fl"),
    ])
    assert svc.stats()["batches"] == 2            # two lanes, not one
    ss1 = ss_sparsify(fn, jax.random.PRNGKey(1))
    ref1 = greedy(fn, 4, alive=ss1.vprime)
    assert (np.asarray(out[0].selected) == np.asarray(ref1.selected)).all()
    ss2 = ss_sparsify(fn, jax.random.PRNGKey(2))
    ref2 = greedy(fn, 4, alive=ss2.vprime)
    assert (np.asarray(out[1].selected) == np.asarray(ref2.selected)).all()


def test_service_n_padding_fl_padding_is_inert():
    """Padded fl queries: the sim's padded rows/columns are zeroed (inert
    for any kernel), and a padded query matches the sequential run on the
    zero-padded-sim ground set."""
    svc = SummarizeService(
        RunConfig(backend="oracle", n_buckets=(64,), max_batch=4)
    )
    X = jnp.abs(jax.random.normal(jax.random.PRNGKey(6), (50, 8)))
    out = svc.run([SummarizeRequest(k=4, key=5, features=X,
                                    objective="fl", kernel="rbf")])[0]
    sim = FacilityLocation.from_features(X, kernel="rbf").sim
    simp = jnp.zeros((64, 64), sim.dtype).at[:50, :50].set(sim)
    fnp = FacilityLocation(sim=simp)
    mask = jnp.arange(64) < 50
    ss = ss_sparsify(fnp, jax.random.PRNGKey(5), alive=mask)
    ref = greedy(fnp, 4, alive=ss.vprime)
    assert (np.asarray(out.selected) == np.asarray(ref.selected)).all()
    assert bool(jnp.all(out.selected < 50))


def test_summarize_batch_compact_under_jit():
    """summarize_batch keeps the post-SS greedy on the compact path even
    under jit (tracer vprime) via the static ss_live_bound — selections
    equal the un-jitted run."""
    fnb, _ = make_fc_batch(B=2, n=256, F=32, seed=41)
    keys = jax.random.split(jax.random.PRNGKey(8), 2)
    host = summarize_batch(fnb, 6, keys)[0]
    jitted = jax.jit(lambda f, k: summarize_batch(f, 6, k)[0].selected)
    np.testing.assert_array_equal(
        np.asarray(host.selected), np.asarray(jitted(fnb, keys))
    )


def test_service_tickets_and_submission_order():
    svc = SummarizeService(RunConfig(backend="oracle", max_batch=2))
    reqs = [
        SummarizeRequest(
            k=4, key=i, features=jnp.asarray(news_day(i, 128, 32)))
        for i in range(3)
    ]
    tickets = [svc.submit(r) for r in reqs]
    assert not any(t.done() for t in tickets)
    out = svc.flush()
    assert all(t.done() for t in tickets)
    assert [t.result() for t in tickets] == out      # submission order
    assert svc.flush() == []                       # queue drained


def test_service_n_padding_collapses_lanes():
    """Opt-in ground-set padding: distinct n share one compile signature;
    pure-greedy queries are padding-invariant."""
    svc = SummarizeService(
        RunConfig(backend="oracle", n_buckets=(256,), max_batch=4)
    )
    reqs = [
        SummarizeRequest(k=4, key=i,
                         features=jnp.asarray(news_day(i, n, 32)),
                         use_ss=False)
        for i, n in enumerate((200, 222, 256))
    ]
    out = svc.run(reqs)
    assert svc.stats()["compiled_signatures"] == 1
    for req, resp in zip(reqs, out):
        ref = greedy(FeatureCoverage(W=jnp.asarray(req.features),
                                     phi="sqrt"), 4)
        assert (np.asarray(resp.selected) == np.asarray(ref.selected)).all()
    with pytest.raises(ValueError, match="n bucket"):
        svc.run([SummarizeRequest(
            k=4, key=9, features=jnp.zeros((300, 32)))])


def test_service_n_padding_ss_matches_padded_sequential():
    """With SS, a padded query matches the sequential run on the padded
    ground set (the documented contract — padding changes the PRNG frame)."""
    svc = SummarizeService(
        RunConfig(backend="oracle", n_buckets=(256,))
    )
    W = jnp.asarray(news_day(0, 200, 32))
    out = svc.run([SummarizeRequest(k=5, key=3, features=W)])[0]
    Wp = jnp.zeros((256, 32), W.dtype).at[:200].set(W)
    fnp = FeatureCoverage(W=Wp, phi="sqrt")
    mask = jnp.arange(256) < 200
    ss = ss_sparsify(fnp, jax.random.PRNGKey(3), alive=mask)
    ref = greedy(fnp, 5, alive=ss.vprime)
    assert (np.asarray(out.selected) == np.asarray(ref.selected)).all()
    assert bool(jnp.all(out.selected < 200))


def test_summarize_batch_shared_with_kv_select():
    """The KV-cache pruning path rides the same execution core: per-row
    selections equal single-row runs."""
    from repro.serve import KVSelectConfig, select_positions_batched
    from repro.serve.kv_select import select_positions

    feats = jnp.stack([
        jnp.abs(jax.random.normal(jax.random.PRNGKey(i), (64, 16)))
        for i in range(3)
    ])
    kv = KVSelectConfig(budget=8)
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    kept = select_positions_batched(feats, kv, keys)
    for i in range(3):
        row = select_positions(feats[i], kv, keys[i])
        np.testing.assert_array_equal(np.asarray(kept[i]), np.asarray(row))


def test_batch_buckets_properties():
    assert batch_buckets(8) == (8, 4, 2, 1)
    assert batch_buckets(1) == (1,)
    for mb in (3, 8, 16):
        bks = batch_buckets(mb)
        assert bks[0] == mb and bks[-1] == 1
        for j in range(1, mb + 1):
            assert min(b for b in bks if b >= j) >= j


def test_sharded_backend_rejects_batched():
    fnb, _ = make_fc_batch(B=2, n=64, F=8)
    with pytest.raises(NotImplementedError, match="micro-batch"):
        ss_sparsify_batched(
            fnb, jax.random.split(jax.random.PRNGKey(0), 2),
            backend="sharded")
