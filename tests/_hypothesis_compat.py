"""Optional-hypothesis shim for the test suite.

``from _hypothesis_compat import given, settings, st`` behaves exactly like
the real hypothesis imports when the package is installed.  When it is not,
``@given(...)``-decorated tests are individually skipped while every plain
test in the same module still collects and runs (a module-level importorskip
would throw those away too).
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in so module-level strategy expressions still evaluate."""

        def __getattr__(self, name):
            return lambda *a, **k: _AnyStrategy()

        def __call__(self, *a, **k):
            return _AnyStrategy()

    st = _AnyStrategy()

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f
