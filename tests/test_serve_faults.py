"""Fault-tolerant serving (PR 8): seeded chaos suite over the recovery loop
(retry → failover → per-query isolation), the watchdog, the degradation
ladder, and the drain/TicketPending semantics.

Invariants pinned here (docs/serving.md "Failure semantics"):

- **no ticket is ever lost**: every admitted ticket settles — with a
  response or a correctly-attributed error — whatever faults its chunk
  attempts drew, and ``drain()`` returns mid-fault (incl. a hung chunk
  abandoned by the watchdog);
- **attribution**: every injected fault lands in ``FaultPlan.log`` with
  exactly the ticket indices of the chunk attempt that drew it;
- **recovery is invisible in the results**: a same-backend retry serves
  results bit-identical to a fault-free run under the same keys; a
  failed-over or isolated query selects identically with gains equal up to
  backend/bucket numerics;
- **the ladder degrades audibly, never silently**: a ladder that never
  triggers is bit-identical to a ladder-free service, every degraded
  response carries its ``degradation`` record, and on a deadline-pressed
  trace the ladder misses strictly fewer deadlines than the full-quality
  scheduler.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import resolve_backend
from repro.data import news_day
from repro.serve import (
    Fault,
    FaultInjected,
    FaultPlan,
    ChunkTimeout,
    MalformedResult,
    RunConfig,
    ServiceRestarted,
    SummarizeRequest,
    SummarizeService,
    TicketPending,
)


def req(i, n=64, F=16, k=4, **kw):
    return SummarizeRequest(
        k=k, key=i, features=jnp.asarray(news_day(i, n, F)), **kw
    )


def _other_backend() -> str:
    """A failover target guaranteed to differ from the session's primary."""
    return "oracle" if resolve_backend(None).name != "oracle" else "pallas"


def assert_same_results(a, b):
    """Bit-identical result payload (same backend + same batch bucket:
    execution is deterministic, so recovery must not perturb a single bit).
    Serving metadata (timing, trigger, recovery) is intentionally not
    compared."""
    assert (np.asarray(a.selected) == np.asarray(b.selected)).all()
    assert (np.asarray(a.gains) == np.asarray(b.gains)).all()
    assert a.value == b.value
    assert a.vprime_size == b.vprime_size
    assert a.eps_hat == b.eps_hat
    assert a.rounds == b.rounds


def assert_equiv_results(a, b):
    """Identical selections, float payload equal up to backend/bucket
    numerics (a failed-over or isolated re-run may execute on a different
    backend or a different batch bucket)."""
    assert (np.asarray(a.selected) == np.asarray(b.selected)).all()
    np.testing.assert_allclose(
        np.asarray(a.gains), np.asarray(b.gains), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(a.value, b.value, rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------- the harness --
def test_fault_plan_seeded_deterministic():
    kw = dict(n_attempts=64, p_exec_error=0.3, p_latency=0.2)
    a = FaultPlan.seeded(7, **kw)
    b = FaultPlan.seeded(7, **kw)
    assert a.schedule == b.schedule and a.schedule
    assert a.schedule != FaultPlan.seeded(8, **kw).schedule
    assert a.log == [] and a.attempts == 0
    with pytest.raises(ValueError, match="probabilities"):
        FaultPlan.seeded(0, p_exec_error=0.9, p_latency=0.9)
    with pytest.raises(ValueError, match="kind"):
        Fault("nope")


def test_fault_plan_draw_consumes_and_logs():
    plan = FaultPlan({1: Fault("exec_error")})
    assert plan.draw(tickets=(0,), lane=("l",), backend="oracle",
                     stage="primary") is None
    f = plan.draw(tickets=(1, 2), lane=("l",), backend="oracle",
                  stage="primary")
    assert f.kind == "exec_error" and plan.attempts == 2
    (ev,) = plan.events()
    assert ev.attempt == 1 and ev.tickets == (1, 2)
    assert plan.events("latency") == []


# ------------------------------------------------------ retry and failover --
def test_exec_error_retried_with_identical_results():
    reqs = [req(i) for i in range(4)]
    ref = SummarizeService(RunConfig(max_batch=4)).run(list(reqs))
    plan = FaultPlan({0: Fault("exec_error")})
    svc = SummarizeService(
        RunConfig(max_batch=4, retry_backoff_s=0.002), faults=plan
    )
    out = svc.run(list(reqs))
    for a, b in zip(out, ref):
        assert_same_results(a, b)
        assert a.recovery is not None
        assert a.recovery["retries"] == 1
        assert a.recovery["stage"] == "primary"
    assert all(r.recovery is None for r in ref)
    (ev,) = plan.events("exec_error")
    assert ev.tickets == (0, 1, 2, 3) and ev.stage == "primary"
    st = svc.stats()
    assert st["retries"] == 1 and st["failed"] == 0 and st["queries"] == 4


def test_malformed_result_detected_and_retried():
    reqs = [req(i) for i in range(3)]
    ref = SummarizeService(RunConfig(max_batch=4)).run(list(reqs))
    plan = FaultPlan({0: Fault("malformed")})
    svc = SummarizeService(
        RunConfig(max_batch=4, retry_backoff_s=0.002), faults=plan
    )
    out = svc.run(list(reqs))
    for a, b in zip(out, ref):
        assert_same_results(a, b)
    assert plan.events("malformed")[0].tickets == (0, 1, 2)
    assert svc.stats()["retries"] == 1


def test_failover_after_retry_exhaustion():
    reqs = [req(i) for i in range(4)]
    ref = SummarizeService(RunConfig(max_batch=4)).run(list(reqs))
    # max_retries=1 -> attempts 0,1 on the primary both fault; attempt 2 is
    # the failover backend's first try and runs clean.
    plan = FaultPlan({0: Fault("exec_error"), 1: Fault("exec_error")})
    cfg = RunConfig(
        max_batch=4, max_retries=1, retry_backoff_s=0.002,
        failover_backend=_other_backend(),
    )
    svc = SummarizeService(cfg, faults=plan)
    out = svc.run(list(reqs))
    for a, b in zip(out, ref):
        assert_equiv_results(a, b)
        assert a.recovery["stage"] == "failover"
        assert a.recovery["backends"] == (
            resolve_backend(None).name, _other_backend()
        )
    assert [e.stage for e in plan.log] == ["primary", "primary"]
    st = svc.stats()
    assert st["failovers"] == 1 and st["failed"] == 0 and st["queries"] == 4


def test_poisoned_query_fails_alone_via_isolation():
    """The headline isolation pin: a NaN payload smuggled past admission
    (validate_payloads=False) poisons every whole-chunk attempt with
    non-finite results, but per-query isolation serves its three chunk
    mates and fails only the poisoned ticket."""
    bad_W = np.array(news_day(99, 64, 16), dtype=np.float32)
    bad_W[3, 5] = np.nan
    good = [req(i) for i in range(3)]
    ref = SummarizeService(RunConfig(max_batch=4)).run(list(good))
    cfg = RunConfig(
        max_batch=4, max_retries=0, retry_backoff_s=0.002,
        failover_backend=None, validate_payloads=False,
    )
    svc = SummarizeService(cfg)
    tickets = [
        svc.submit(good[0]),
        svc.submit(SummarizeRequest(k=4, key=99, features=jnp.asarray(bad_W))),
        svc.submit(good[1]),
        svc.submit(good[2]),
    ]
    svc.flush()
    for t, r in zip([tickets[0], tickets[2], tickets[3]], ref):
        resp = t.result(timeout=0)
        assert resp.recovery["isolated"] is True
        assert resp.recovery["stage"] == "isolated"
        assert_equiv_results(resp, r)
    with pytest.raises(MalformedResult):
        tickets[1].result(timeout=0)
    st = svc.stats()
    assert st["isolated_queries"] == 3 and st["failed"] == 1


# ------------------------------------------------- admission + drain fixes --
def test_admission_rejects_nonfinite_payload_and_bad_k():
    svc = SummarizeService(RunConfig(max_batch=4))
    W = np.array(news_day(0, 32, 8), dtype=np.float32)
    W[0, 0] = np.inf
    t_inf = svc.submit(SummarizeRequest(k=4, key=0, features=jnp.asarray(W)))
    assert t_inf.done()
    with pytest.raises(ValueError, match="non-finite"):
        t_inf.result()
    t_k = svc.submit(req(1, k=0))
    assert t_k.done()
    with pytest.raises(ValueError, match="k must be"):
        t_k.result()
    good = svc.submit(req(2))
    svc.flush()
    assert good.result().value > 0
    assert svc.stats()["failed"] == 2 and svc.stats()["queries"] == 1


def test_drain_timeout_leaves_ticket_pending_not_blocked():
    """The PR-8 drain fix: when drain(timeout) gives up on an in-flight
    chunk, a bounded wait on its ticket raises TicketPending naming the
    state instead of blocking forever; the chunk still lands afterwards."""
    # Warm the signature first so the in-flight window is the injected
    # latency, not an unpredictable first compile.
    SummarizeService(RunConfig(max_batch=2)).run([req(50)])
    plan = FaultPlan({0: Fault("latency", delay_s=1.5)})
    cfg = RunConfig(scheduler="async", max_batch=2, max_wait_s=0.01)
    with SummarizeService(cfg, faults=plan) as svc:
        t = svc.submit(req(0))
        with pytest.raises(TimeoutError, match="drain timeout"):
            svc.drain(timeout=0.3)
        assert t.state() == "executing" and not t.done()
        with pytest.raises(TicketPending, match="executing"):
            t.result(timeout=0.05)
        svc.drain(timeout=60)
        assert t.done() and t.result().value > 0


# ------------------------------------------------------- the 32-query pin --
@pytest.mark.timeout(300)
def test_chaos_acceptance_32_query_async_hang_and_errors():
    """The ISSUE acceptance run: a seeded plan injecting exec errors and one
    hung chunk into a 32-query async trace.  Zero lost tickets, drain()
    returns, and every query — faulted chunks included — is served; the
    non-faulted queries bit-identical to the fault-free run."""
    N, B = 32, 4
    other = _other_backend()
    # Warm every signature the run can touch (both backends, full bucket)
    # so chunk_timeout_s bounds *execution*, not an unpredictable compile.
    for be in (None, other):
        SummarizeService(RunConfig(max_batch=B, backend=be)).run(
            [req(100 + i) for i in range(B)]
        )
    cfg = RunConfig(
        scheduler="async", max_batch=B, max_wait_s=0.02,
        retry_backoff_s=0.005, chunk_timeout_s=2.0,
        failover_backend=other,
    )
    reqs = [req(i) for i in range(N)]
    with SummarizeService(
        dataclasses.replace(cfg, chunk_timeout_s=None)
    ) as ref_svc:
        ref_tickets = [ref_svc.submit(r) for r in reqs]
        ref_svc.drain(timeout=240)
        ref = [t.result(timeout=0) for t in ref_tickets]
    # Attempt schedule (all 32 queries submitted upfront -> deterministic
    # full-trigger chunks of 4): chunk0 clean, chunk1 errors once then
    # retries clean, chunk2 hangs (watchdog abandons it at 2s; the worker's
    # 4s sleep ends after failover already served its tickets), the rest
    # run clean.
    plan = FaultPlan({1: Fault("exec_error"), 3: Fault("hang", delay_s=4.0)})
    with SummarizeService(cfg, faults=plan) as svc:
        tickets = [svc.submit(r) for r in reqs]
        svc.drain(timeout=240)
        assert all(t.done() for t in tickets)          # zero lost tickets
        out = [t.result(timeout=0) for t in tickets]   # every query served
    faulted = set()
    for ev in plan.log:
        faulted |= set(ev.tickets)
    assert faulted and faulted <= set(range(N))
    for i, (a, b) in enumerate(zip(out, ref)):
        if i in faulted:
            assert_equiv_results(a, b)   # recovered on another backend
            assert a.recovery is not None
        else:
            assert_same_results(a, b)    # untouched by any fault: bit-equal
    st = svc.stats()
    assert st["failed"] == 0 and st["queries"] == N
    assert st["chunk_timeouts"] == 1
    assert st["retries"] >= 1
    (hang_ev,) = plan.events("hang")
    assert len(hang_ev.tickets) == B and hang_ev.stage == "primary"


# ------------------------------------------------------------ chaos matrix --
@pytest.mark.timeout(300)
@pytest.mark.parametrize("scheduler", ["sync", "async"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_matrix(seed, scheduler):
    """Seeded fault soup (exec errors, latency spikes, malformed results)
    across schedulers: no ticket lost, every fault attributed to admitted
    tickets, every served response identical to the fault-free reference,
    every failed ticket failed by an injected fault — and the books
    balance."""
    Q = 8
    reqs = [req(i) for i in range(Q)]
    ref = SummarizeService(RunConfig(max_batch=4)).run(list(reqs))
    plan = FaultPlan.seeded(
        seed, n_attempts=64,
        p_exec_error=0.25, p_latency=0.15, p_malformed=0.1, latency_s=0.01,
    )
    cfg = RunConfig(
        max_batch=4, scheduler=scheduler, max_wait_s=0.05,
        retry_backoff_s=0.002, failover_backend=_other_backend(),
    )
    svc = SummarizeService(cfg, faults=plan)
    tickets = [svc.submit(r) for r in reqs]
    if scheduler == "sync":
        svc.flush()
    else:
        svc.drain(timeout=240)
        svc.stop()
    assert all(t.done() for t in tickets)              # no ticket lost
    served = failed = 0
    for t, r in zip(tickets, ref):
        err = t.exception(timeout=0)
        if err is None:
            assert_equiv_results(t.result(timeout=0), r)
            served += 1
        else:
            # only an injected fault may fail a ticket here, and only after
            # the whole recovery path was itself fault-poisoned
            assert isinstance(
                err, (FaultInjected, MalformedResult, ChunkTimeout)
            )
            failed += 1
    st = svc.stats()
    assert served + failed == Q
    assert st["queries"] == served and st["failed"] == failed
    for ev in plan.log:
        assert set(ev.tickets) <= set(range(Q))
    if scheduler == "sync":
        # deterministic chunking: at these rates the recovery path must
        # serve every query (verified for seeds 0-2)
        assert failed == 0


# ------------------------------------------------------- degradation ladder --
def test_ladder_never_triggered_is_bit_identical():
    reqs = [req(i) for i in range(4)]
    base = SummarizeService(RunConfig(max_batch=4)).run(list(reqs))
    lad = SummarizeService(
        RunConfig(max_batch=4, ladder=("stochastic_greedy", "bump_c"))
    ).run(list(reqs))
    for a, b in zip(lad, base):
        assert_same_results(a, b)
        assert a.degradation is None


def test_ladder_force_records_and_is_reproducible():
    cfg = RunConfig(
        max_batch=4, ladder=("stochastic_greedy", "bump_c", "shrink_r"),
        ladder_force=3,
    )
    reqs = [req(i, n=128, F=24) for i in range(4)]
    svc = SummarizeService(cfg)
    out1 = svc.run(list(reqs))
    out2 = SummarizeService(cfg).run(list(reqs))
    for a, b in zip(out1, out2):
        assert_same_results(a, b)   # degraded execution is seeded, not noisy
    for resp in out1:
        d = resp.degradation
        assert d["steps"] == ("stochastic_greedy", "bump_c", "shrink_r")
        assert d["level"] == 3 and d["reason"] == "forced"
        assert d["selector"] == "stochastic"
        assert d["r"] == 4 and d["c"] == 32.0
        assert len(resp.selected) == 4 and resp.value > 0
    assert svc.stats()["degraded"] == 4


def test_ladder_pressure_degrades_under_load():
    cfg = RunConfig(
        max_batch=2, max_pending=4, ladder=("bump_c",), ladder_pressure=0.5,
    )
    svc = SummarizeService(cfg)
    tickets = [svc.submit(req(i)) for i in range(4)]
    svc.flush()
    for t in tickets:
        d = t.result(timeout=0).degradation
        assert d is not None and d["reason"] == "pressure"
        assert d["steps"] == ("bump_c",) and d["c"] == 32.0
    assert svc.stats()["degraded"] == 4


def test_invalid_config_rejected():
    with pytest.raises(ValueError, match="ladder step"):
        RunConfig(ladder=("warp_speed",))
    with pytest.raises(ValueError, match="ladder_pressure"):
        RunConfig(ladder_pressure=0.0)
    with pytest.raises(ValueError, match="max_retries"):
        RunConfig(max_retries=-1)
    with pytest.raises(ValueError, match="chunk_timeout_s"):
        RunConfig(chunk_timeout_s=0.0)


@pytest.mark.timeout(300)
def test_ladder_beats_full_quality_on_deadline_trace():
    """The acceptance comparison: on the same deadline-pressed trace, the
    ladder-enabled scheduler misses strictly fewer deadlines than the PR-7
    (full-quality-only) scheduler, and every degraded response carries its
    audit record."""
    # SS-side steps only: at CPU-test sizes the wall-clock win comes from
    # fewer/cheaper SS rounds (measured ~0.55x at n=4096); the stochastic
    # selector's win needs compact buckets far wider than its sample size.
    n, F, k, B = 4096, 32, 16, 2
    ladder = ("bump_c", "shrink_r")

    def mk(i, dl=None):
        return SummarizeRequest(
            k=k, key=i, features=jnp.asarray(news_day(i, n, F)),
            deadline_s=dl,
        )

    base_cfg = RunConfig(max_batch=B)

    def steady_exec(svc, base_key):
        # One chunk shares one exec_s sample; best-of-3 batches smooths the
        # scheduler/allocator noise that a single sample is hostage to.
        return min(
            svc.run([mk(base_key + 10 * rep + i) for i in range(B)])[0].exec_s
            for rep in range(3)
        )

    # Warm both quality levels and measure their steady-state exec time.
    svc_f = SummarizeService(base_cfg)
    svc_f.run([mk(100 + i) for i in range(B)])
    exec_full = steady_exec(svc_f, 110)
    svc_d = SummarizeService(
        dataclasses.replace(base_cfg, ladder=ladder, ladder_force=len(ladder))
    )
    svc_d.run([mk(200 + i) for i in range(B)])
    exec_deg = steady_exec(svc_d, 210)
    if not exec_deg < 0.7 * exec_full:
        pytest.skip(
            f"degraded/full exec ratio {exec_deg / exec_full:.2f} leaves no "
            "reliable deadline window on this machine"
        )
    deadline = 0.5 * (exec_full + exec_deg)

    def run_policy(ladder_cfg):
        svc = SummarizeService(
            dataclasses.replace(base_cfg, ladder=ladder_cfg)
        )
        svc.run([mk(140 + i) for i in range(B)])   # seed the (lane, 0) EWMA
        tickets = [svc.submit(mk(150 + i, dl=deadline)) for i in range(B)]
        svc.flush()
        return [t.result(timeout=0) for t in tickets], svc.stats()

    out_full, st_full = run_policy(())
    out_lad, st_lad = run_policy(ladder)
    assert st_full["deadlines_missed"] >= 1        # PR-7 behavior: misses
    assert st_lad["deadlines_missed"] < st_full["deadlines_missed"]
    for r in out_lad:
        assert r.degradation is not None
        assert r.degradation["reason"] == "deadline"
        assert r.degradation["steps"][0] == "bump_c"


# ------------------------------------------------------------- crash/restart -

def test_crash_settles_every_ticket_and_poisons_admission():
    """A crash fault mid-chunk: every in-flight ticket settles with
    ServiceRestarted — no ticket is ever left hanging in TicketPending —
    and the dead service rejects new submissions with the same error."""
    svc = SummarizeService(
        RunConfig(max_batch=4), faults=FaultPlan({0: Fault("crash")})
    )
    tickets = [svc.submit(req(i)) for i in range(4)]
    svc.flush()
    for t in tickets:
        assert t.done()
        assert isinstance(t.exception(timeout=0), ServiceRestarted)
        with pytest.raises(ServiceRestarted):
            t.result(timeout=0)
    st = svc.stats()
    assert st["restarts"] == 1 and st["failed"] == 4
    late = svc.submit(req(9))            # admission is poisoned, not hung
    assert isinstance(late.exception(timeout=0), ServiceRestarted)


def test_crash_async_tickets_never_hang():
    """Same pin on the async scheduler: the flusher absorbs the crash,
    drain() returns (nothing stays outstanding), and queued chunk-mates in
    *other* lanes settle with ServiceRestarted too."""
    plan = FaultPlan({0: Fault("crash")})
    cfg = RunConfig(scheduler="async", max_batch=2, max_wait_s=0.01)
    with SummarizeService(cfg, faults=plan) as svc:
        tickets = [svc.submit(req(i)) for i in range(2)]
        tickets += [svc.submit(req(10 + i, n=32)) for i in range(2)]  # 2nd lane
        svc.drain(timeout=120)
        for t in tickets:
            assert t.done()
            assert isinstance(t.exception(timeout=0), ServiceRestarted)


def test_restart_settles_in_flight_but_keeps_serving():
    """A restart fault: the in-flight chunk settles with ServiceRestarted
    (its queue state is gone), but the service comes back — subsequent
    submissions execute normally, bit-identical to a fault-free service."""
    svc = SummarizeService(
        RunConfig(max_batch=2), faults=FaultPlan({0: Fault("restart")})
    )
    first = [svc.submit(req(i)) for i in range(2)]
    svc.flush()
    for t in first:
        assert isinstance(t.exception(timeout=0), ServiceRestarted)
    out = svc.run([req(10 + i) for i in range(2)])     # serving resumed
    want = SummarizeService(RunConfig(max_batch=2)).run(
        [req(10 + i) for i in range(2)]
    )
    for a, b in zip(out, want):
        assert_same_results(a, b)
    assert svc.stats()["restarts"] == 1
